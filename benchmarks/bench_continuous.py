"""Beyond the paper's §5.6 — static vs bin-packed vs continuous serving.

The paper raised CPU utilization 43% by overlapping *batches* in parallel
streams; the next structural win is overlapping *requests inside the decode
grid*.  Workload: the synthetic corpus with a **skewed generation-length
distribution** (75% short / 25% long budgets, uncorrelated with source
length — at schedule time real decode lengths are unknown), which is the
regime where a static batch idles most of its rows waiting for the longest
request.

Both engines run with ``burst_len=1`` (the per-step decode loop) so the
comparison isolates *scheduling*; the decode-burst dimension is swept by
``bench_decode_burst.py``.  Warmup passes absorb jit compilation and are
reported as their own row instead of being folded into wall time.

Rows:

* ``pack_pad_waste_*``     — prefill pad waste: fixed-size token-sorted
  batches vs first-fit-decreasing token-budget bins.
* ``compile_warmup``       — jit compile + warmup seconds per path
  (excluded from every measured row below).
* ``serve_static_sorted``  — measured tokens/s + decode-grid utilization for
  the paper's static path (token-sorted fixed batches via ``generate``).
* ``serve_continuous``     — measured tokens/s + utilization for the
  slot-refill engine (``serve``) with FFD admission order.
* ``continuous_speedup``   — measured ratio plus the deterministic queue
  model's prediction (``simulate_continuous``).
* ``serve_fused_admission`` / ``serve_unfused_admission`` — fused-admission
  A/B on a refill-heavy config (every burst drains its whole grid, every
  round admits): the unfused baseline pays a prefill dispatch + first-token
  drain *and* a burst drain per round, fused admission rides the burst
  program — one dispatch, one sync.  Token identity between the two paths
  and the ≥2× host-sync reduction per request are **asserted**, so the CI
  bench-smoke job fails on any dispatch-count regression.
* ``token_identity``       — continuous greedy output equals per-request
  ``generate`` output, token for token.
* ``prefix_cache_*``       — repeated-prefix admission mix served cold vs
  with ``prefix_cache=True``: output token identity, the proportional
  ``encoder_tokens`` cut (a hit skips the encoder entirely), ≥1 reused
  chain page per hit, and an all-hit / zero-allocation re-serve on the
  warmed engine are all **asserted** for CI.
* ``preempt_*``            — overload section on a bimodal workload:
  ``overcommit`` A/B on a page pool deliberately too small for the
  worst-case reservation (strictly higher admitted concurrency with token
  identity is **asserted**), a chunked-prefill A/B on a long/short source
  mix (a lower worst first-token latency for the short interactive
  requests is **asserted** — long sources stage one encoder layer per
  round instead of head-of-line-blocking the admission encode), and a
  chaos run reporting preemption/spill traffic (fired preemptions, token
  identity, and full page + spill-store reclaim are **asserted**).
* ``admission_enc_bucket`` — compile-variant regression: a serve sweep
  over several source-length mixes compiles one fused-burst variant per
  distinct ``enc_len`` under ``admission_enc_bucket="exact"`` but
  converges onto a single pow2 bucket under the ``"max"`` default; the
  variant-count drop is **asserted** (CI fails if the bucketing stops
  deduplicating programs).

``--smoke`` shrinks the request count and measurement passes for CI;
``--only SUBSTR`` runs just the sections whose name contains ``SUBSTR``
(``pack``, ``continuous``, ``fused``, ``bucket``, ``prefix``,
``preempt``).
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from benchmarks.common import measure
from repro.configs import get_config
from repro.data import make_corpus, pack_batches_token_budget, padding_stats
from repro.data.sorting import make_batches
from repro.data.synthetic import pad_batch
from repro.models import build_model
from repro.serving import ServingEngine, TokenSortedScheduler, make_chaos, \
    simulate_continuous

N_REQUESTS = 96
BATCH_SIZE = 16
N_SLOTS = 16
SHORT_BUDGET, LONG_BUDGET = 4, 48
P_SHORT = 0.75
MEASURE_PASSES = 3          # paired passes; median ratio damps load noise

# fused-admission A/B: every request finishes inside one burst (budget ≤
# burst), so every round admits a full grid — the admission-bound regime
# where the per-round prefill dispatch is half the host traffic
FUSED_SLOTS = 4
FUSED_BURST = 8
FUSED_BUDGET = 6


def _engine_and_requests(n_requests: int):
    cfg = get_config("transformer-base").reduced(
        vocab=64, d_model=96, n_layers=2, n_enc_layers=2, d_ff=192,
        n_heads=4, n_kv_heads=4, head_dim=24)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServingEngine(model, params, max_len=64, burst_len=1)
    requests = make_corpus(n_requests, cfg.vocab, seed=9)
    rng = np.random.default_rng(0)
    budgets = np.where(rng.random(n_requests) < P_SHORT,
                       SHORT_BUDGET, LONG_BUDGET).astype(int)
    return engine, requests, budgets


def _run_static(engine, requests, budgets):
    """Paper path: token-sorted fixed batches, batch runs to its max budget."""
    sched = TokenSortedScheduler(batch_size=BATCH_SIZE)
    items = sched.plan(requests)
    t0 = time.perf_counter()
    delivered = 0
    grid = 0
    for item in items:
        cap = int(max(budgets[i] for i in item.indices))
        res = engine.generate(item.batch, max_new_tokens=cap)
        grid += res.steps * len(item.indices)
        for local, gi in enumerate(item.indices):
            delivered += min(len(res.tokens[local]), int(budgets[gi]))
    wall = time.perf_counter() - t0
    return delivered, wall, delivered / max(grid, 1)


def _run_continuous(engine, requests, budgets):
    """Slot-refill path, FFD bin-packed admission order."""
    bins = pack_batches_token_budget(requests, token_budget=256)
    order = [i for b in bins for i in b]
    t0 = time.perf_counter()
    res = engine.serve([requests[i] for i in order], n_slots=N_SLOTS,
                       max_new_tokens=[int(budgets[i]) for i in order])
    wall = time.perf_counter() - t0
    return res, order, wall


def run(smoke: bool = False, only: str = None) -> list:
    rows = []
    n_requests = 24 if smoke else N_REQUESTS
    passes = 1 if smoke else MEASURE_PASSES
    engine, requests, budgets = _engine_and_requests(n_requests)

    def want(section: str) -> bool:
        return only is None or only in section

    # 1 — prefill pad waste: fixed-size sorted batches vs FFD budget bins
    if want("pack"):
        fixed = padding_stats(requests, make_batches(requests, BATCH_SIZE,
                                                     "tokens"))
        ffd = padding_stats(requests,
                            pack_batches_token_budget(requests, 256))
        rows.append(("pack_pad_waste_fixed16", 0.0,
                     f"pad_waste={fixed['pad_waste']:.4f}"))
        rows.append(("pack_pad_waste_ffd256", 0.0,
                     f"pad_waste={ffd['pad_waste']:.4f}"))

    if not want("continuous"):
        rows.extend(_fused_rows(engine, requests, smoke, passes)
                    if want("fused") else [])
        rows.extend(_bucket_rows(engine) if want("bucket") else [])
        rows.extend(_prefix_rows(engine, requests, smoke)
                    if want("prefix") else [])
        rows.extend(_preempt_rows(engine, smoke) if want("preempt") else [])
        rows.extend(_weightbits_rows(smoke, passes)
                    if want("weightbits") else [])
        return rows

    # 2 — warmup both paths (jit compile, timed and reported separately),
    # then measure in interleaved pairs: each pass runs static then
    # continuous back-to-back so shared-machine load noise hits both; the
    # median paired ratio is the speedup
    _, _, warm_static_s = measure(
        lambda: _run_static(engine, requests, budgets), warmup=1, passes=0)
    _, _, warm_cont_s = measure(
        lambda: _run_continuous(engine, requests, budgets), warmup=1,
        passes=0)
    rows.append(("compile_warmup", 0.0,
                 f"static_s={warm_static_s:.2f} "
                 f"continuous_s={warm_cont_s:.2f} (excluded from rows below)"))

    statics, continuous, ratios = [], [], []
    for _ in range(passes):
        s = _run_static(engine, requests, budgets)
        c = _run_continuous(engine, requests, budgets)
        statics.append(s)
        continuous.append(c)
        ratios.append((c[0].n_tokens / c[2]) / (s[0] / s[1]))

    s_tok, s_wall, s_util = min(statics, key=lambda r: r[1])
    rows.append(("serve_static_sorted", s_wall * 1e6 / n_requests,
                 f"tok_per_s={s_tok / s_wall:.1f} grid_util={s_util:.3f}"))

    res, order, c_wall = min(continuous, key=lambda r: r[2])
    rows.append(("serve_continuous", c_wall * 1e6 / n_requests,
                 f"tok_per_s={res.n_tokens / c_wall:.1f} "
                 f"grid_util={res.utilization:.3f} "
                 f"first_tok_p95_s={res.metrics()['first_token_latency_p95_s']:.3f}"))

    speedup = float(np.median(ratios))
    sim = simulate_continuous([int(b) for b in budgets], N_SLOTS,
                              static_batch=BATCH_SIZE)
    rows.append(("continuous_speedup", 0.0,
                 f"measured={speedup:.2f}x "
                 f"queue_model={sim['speedup_steps']:.2f}x "
                 f"(static_util={sim['static_utilization']:.2f} "
                 f"cont_util={sim['continuous_utilization']:.2f})"))

    # 3 — fused admission A/B (hard invariants, CI fails on regression)
    if want("fused"):
        rows.extend(_fused_rows(engine, requests, smoke, passes))

    # 4 — token identity: serve() output == per-request generate()
    mismatches = 0
    for i in range(0, n_requests, 12):
        src, lens = pad_batch([requests[i].src])
        g = engine.generate({"src_tokens": src, "src_lengths": lens},
                            max_new_tokens=int(budgets[i]))
        if not np.array_equal(np.asarray(g.tokens[0]), res.tokens_for(
                order.index(i))):
            mismatches += 1
    rows.append(("token_identity", 0.0,
                 f"mismatches={mismatches}/{len(range(0, n_requests, 12))}"))

    # 5 — admission enc_len bucketing (asserted compile-variant dedup)
    if want("bucket"):
        rows.extend(_bucket_rows(engine))

    # 6 — prefix cache on a repeated-prefix mix (asserted identity + cut)
    if want("prefix"):
        rows.extend(_prefix_rows(engine, requests, smoke))

    # 7 — overload: overcommit / chunked prefill / chaos (asserted)
    if want("preempt"):
        rows.extend(_preempt_rows(engine, smoke))

    # 8 — weight-bits A/B: INT8 vs block-wise INT4 weights (asserted)
    if want("weightbits"):
        rows.extend(_weightbits_rows(smoke, passes))
    return rows


def _fused_rows(engine, requests, smoke: bool, passes: int) -> list:
    """Fused admission A/B: same workload, fused_admission on/off.

    Identity and the ≥2× host-sync cut are hard invariants (CI fails on
    regression): with budgets ≤ burst_len and requests ≡ 0 mod slots,
    unfused pays exactly 2 syncs/round (prefill drain + burst drain),
    fused exactly 1.
    """
    rows = []
    n_fused = 12 if smoke else 32
    fused_reqs = requests[:n_fused]
    caps = [FUSED_BUDGET] * n_fused
    run_ab = lambda fused: engine.serve(
        fused_reqs, n_slots=FUSED_SLOTS, max_new_tokens=caps,
        burst_len=FUSED_BURST, fused_admission=fused)
    fused, f_times, warm_f = measure(lambda: run_ab(True), warmup=1,
                                     passes=passes)
    unfused, u_times, warm_u = measure(lambda: run_ab(False), warmup=1,
                                       passes=passes)
    rows.append(("compile_warmup_fused", 0.0,
                 f"fused_s={warm_f:.2f} unfused_s={warm_u:.2f} "
                 "(excluded from rows below)"))
    for i in range(n_fused):
        assert np.array_equal(fused.tokens_for(i), unfused.tokens_for(i)), (
            f"fused admission diverged from the unfused path on request {i}")
    assert fused.prefill_dispatches == 0, (
        "fused admission dispatched a separate prefill "
        f"({fused.prefill_dispatches} times)")
    assert unfused.host_syncs >= 2 * fused.host_syncs, (
        "fused admission must cut host syncs ≥2× on the admission-bound "
        f"config: fused={fused.host_syncs} unfused={unfused.host_syncs}")
    rows.append(("serve_fused_admission", min(f_times) * 1e6 / n_fused,
                 f"tok_per_s={fused.n_tokens / min(f_times):.1f} "
                 f"host_syncs_per_req={fused.host_syncs / n_fused:.2f} "
                 f"prefill_dispatches={fused.prefill_dispatches} "
                 f"encoder_tokens={fused.encoder_tokens}"))
    rows.append(("serve_unfused_admission", min(u_times) * 1e6 / n_fused,
                 f"tok_per_s={unfused.n_tokens / min(u_times):.1f} "
                 f"host_syncs_per_req={unfused.host_syncs / n_fused:.2f} "
                 f"prefill_dispatches={unfused.prefill_dispatches} "
                 f"encoder_tokens={unfused.encoder_tokens} "
                 f"sync_cut={unfused.host_syncs / max(fused.host_syncs, 1):.2f}x"))
    return rows


def _bucket_rows(engine) -> list:
    """Admission enc_len bucketing: sweep serves over three source-length
    mixes (longest first, the steady-state of a sweep).  The state
    cross-K/V buffers and fused-admission inputs are enc_len-shaped, so
    "exact" respecializes every burst program per mix while "max" reuses
    the single pow2 bucket — asserted, with the drop reported.  Fresh
    engines so prior rows' caches don't pollute counts.
    """
    cfg = engine.model.cfg
    sweep_sets = [make_corpus(8, cfg.vocab, seed=20 + i, max_words=w)
                  for i, w in enumerate((12, 6, 2))]

    def run_sweep(eng):
        for sub in sweep_sets:
            eng.serve(sub, n_slots=4, max_new_tokens=3, burst_len=4)
        return eng.compiled_variants()

    v_exact = run_sweep(ServingEngine(engine.model, engine.params,
                                      max_len=64,
                                      admission_enc_bucket="exact"))
    v_max = run_sweep(ServingEngine(engine.model, engine.params, max_len=64,
                                    admission_enc_bucket="max"))
    if v_exact is None or v_max is None:
        # this jax exposes no jit-cache introspection: report, don't guess
        return [("admission_enc_bucket", 0.0,
                 "variant counting unavailable on this jax version")]
    assert v_max < v_exact, (
        "admission_enc_bucket='max' must compile fewer burst-program "
        f"variants than 'exact' over a source-length sweep: {v_max} vs "
        f"{v_exact}")
    return [("admission_enc_bucket", 0.0,
             f"variants_max={v_max} variants_exact={v_exact} "
             f"cut={v_exact / max(v_max, 1):.2f}x "
             f"(3 source-length mixes, one serve each)")]


def _prefix_rows(engine, requests, smoke: bool) -> list:
    """Prefix-cache A/B on a repeated-prefix admission mix.

    Each distinct source appears ``repeat`` times, so a warm serve should
    encode each source once and hit the cache for the other
    ``repeat - 1`` admissions.  Asserted (the CI smoke step runs this
    section): per-request token identity against a cold-cache serve, the
    *exactly* proportional ``encoder_tokens`` cut (a hit skips the
    encoder entirely, so warm·n == cold·(n − hits)), ≥1 reused chain page
    per hit, and an all-hit / zero-new-pages re-serve on the warm engine.
    """
    rows = []
    n_uniq = 4 if smoke else 8
    repeat = 3
    mix = [requests[i % n_uniq] for i in range(n_uniq * repeat)]
    n = len(mix)
    caps = [FUSED_BUDGET] * n
    cold_eng = ServingEngine(engine.model, engine.params, max_len=64)
    warm_eng = ServingEngine(engine.model, engine.params, max_len=64,
                             prefix_cache=True, prefix_pages=64)
    serve = lambda eng: eng.serve(mix, n_slots=FUSED_SLOTS,
                                  max_new_tokens=caps,
                                  burst_len=FUSED_BURST)
    cold, _, _ = measure(lambda: serve(cold_eng), warmup=1, passes=1)
    t0 = time.perf_counter()
    warm = serve(warm_eng)
    warm_wall = time.perf_counter() - t0
    for i in range(n):
        assert np.array_equal(cold.tokens_for(i), warm.tokens_for(i)), (
            f"prefix cache changed request {i}'s tokens")
    hits = warm.prefix_hits
    assert hits >= 1, "repeated-prefix mix produced no cache hits"
    assert warm.encoder_tokens * n == cold.encoder_tokens * (n - hits), (
        "encoder_tokens must drop exactly proportionally to the hit "
        f"rate: cold={cold.encoder_tokens} warm={warm.encoder_tokens} "
        f"hits={hits}/{n}")
    assert warm.prefix_hit_pages >= hits, (
        "every hit must reuse at least one cached chain page: "
        f"{warm.prefix_hit_pages} pages for {hits} hits")
    met = warm.metrics()
    rows.append(("prefix_cache_warm", warm_wall * 1e6 / n,
                 f"hit_rate={met['prefix_hit_rate']:.2f} "
                 f"encoder_tokens={warm.encoder_tokens} "
                 f"(cold={cold.encoder_tokens}) "
                 f"hit_pages={warm.prefix_hit_pages} "
                 f"chains={warm.prefix_chains}"))
    # re-serve on the warmed engine: every admission hits, no new pages
    t0 = time.perf_counter()
    rewarm = serve(warm_eng)
    rewarm_wall = time.perf_counter() - t0
    for i in range(n):
        assert np.array_equal(cold.tokens_for(i), rewarm.tokens_for(i)), (
            f"warmed prefix cache changed request {i}'s tokens")
    assert rewarm.prefix_hits == n and rewarm.prefix_pages_allocated == 0, (
        "re-serving the same mix on a warmed engine must hit on every "
        f"admission with zero new chain pages: hits={rewarm.prefix_hits}"
        f"/{n}, allocated={rewarm.prefix_pages_allocated}")
    assert rewarm.encoder_tokens == 0, (
        f"all-hit serve still encoded {rewarm.encoder_tokens} row-tokens")
    rows.append(("prefix_cache_rewarm", rewarm_wall * 1e6 / n,
                 f"hit_rate={rewarm.metrics()['prefix_hit_rate']:.2f} "
                 f"encoder_tokens=0 pages_allocated=0 "
                 f"evictions={rewarm.prefix_evictions}"))
    return rows


def _preempt_rows(engine, smoke: bool) -> list:
    """Overload section on a bimodal workload (hard invariants, CI fails
    on regression).

    * overcommit A/B: the page pool holds 2 worst-case rows, so at
      ``overcommit=1.0`` admission reserves conservatively and the grid
      runs starved; ``overcommit=1.5`` admits past the worst case and
      covers the gap with growth + preempt-by-page-spill.  Strictly
      higher ``peak_running``, per-request token identity, and full
      page/spill reclaim are asserted.
    * chunked prefill A/B: long sources ahead of short interactive ones.
      Monolithic admission encodes the whole mix before anyone's first
      token; with ``prefill_chunk`` the long sources stage one encoder
      layer per serving round while the shorts admit and decode
      immediately.  The shorts' worst first-token latency (best of
      ``passes`` paired runs) must strictly drop, with token identity.
    * chaos: a seeded preempt-every-round schedule on the starved pool —
      preemptions must fire, tokens stay identical, everything reclaims.
    """
    rows = []
    cfg = engine.model.cfg
    passes = 2 if smoke else MEASURE_PASSES

    # --- overcommit A/B on a starved pool (2 worst-case rows of 20-token
    # budgets; the 4-token shorts make the reservation gap bimodal)
    n = 6
    reqs = make_corpus(n, cfg.vocab, seed=41, max_words=6)
    budgets = [20 if i % 2 == 0 else 4 for i in range(n)]
    peng = ServingEngine(engine.model, engine.params, max_len=32,
                         paged=True, page_size=8, n_pages=6)
    serve_oc = lambda oc: peng.serve(reqs, n_slots=4, max_new_tokens=budgets,
                                     burst_len=4, overcommit=oc)
    # one warm serve absorbs compile — at the highest level, so the growth/
    # spill/resume programs it alone exercises are also warm (overcommit is
    # host-side: every level reuses the same programs) — then one timed
    # serve per level reporting first-token p50/p99 vs the occupancy bought
    # ... and at 1.0, whose narrower admission widths bucket differently
    _, _, warm_s = measure(lambda: (serve_oc(1.5), serve_oc(1.0)),
                           warmup=1, passes=0)
    by_level = {}
    for lvl in (1.0, 1.25, 1.5):
        t0 = time.perf_counter()
        r = serve_oc(lvl)
        wall = time.perf_counter() - t0
        by_level[lvl] = r
        ft = [q.first_token_latency_s for q in r.requests
              if q.first_token_latency_s is not None]
        p50, p99 = np.percentile(ft, [50, 99])
        rows.append((f"preempt_overcommit_{lvl:g}", wall * 1e6 / n,
                     f"peak_running={r.peak_running} "
                     f"grid_util={r.utilization:.3f} "
                     f"first_tok_p50_s={p50:.4f} p99_s={p99:.4f} "
                     f"preemptions={r.preemptions} "
                     f"spilled_bytes={r.spilled_bytes} "
                     f"free_lwm={r.free_lwm}" +
                     (f" (compile_s={warm_s:.2f})" if lvl == 1.0 else "")))
    base, oc = by_level[1.0], by_level[1.5]
    for lvl, r in by_level.items():
        for i in range(n):
            assert np.array_equal(base.tokens_for(i), r.tokens_for(i)), (
                f"overcommit={lvl} changed request {i}'s tokens")
        assert r.pages_in_use == 0 and r.spill_events == r.restore_events, (
            f"overcommit={lvl} serve leaked: pages_in_use={r.pages_in_use} "
            f"spills={r.spill_events} restores={r.restore_events}")
    assert oc.peak_running > base.peak_running, (
        "overcommit=1.5 must strictly raise admitted concurrency on the "
        f"starved pool: base={base.peak_running} oc={oc.peak_running}")

    # --- chaos on the same starved pool: forced evictions every round
    chaos_res = peng.serve(reqs, n_slots=4, max_new_tokens=budgets,
                           burst_len=4,
                           chaos=make_chaos(4, n_rounds=64, preempt_every=1))
    for i in range(n):
        assert np.array_equal(base.tokens_for(i), chaos_res.tokens_for(i)), (
            f"chaos schedule changed request {i}'s tokens")
    assert chaos_res.preemptions > 0, "chaos schedule never fired"
    assert chaos_res.pages_in_use == 0 and \
        chaos_res.spill_events == chaos_res.restore_events, (
            f"chaos serve leaked: pages_in_use={chaos_res.pages_in_use} "
            f"spills={chaos_res.spill_events} "
            f"restores={chaos_res.restore_events}")
    rows.append(("preempt_chaos", 0.0,
                 f"preemptions={chaos_res.preemptions} "
                 f"spill_events={chaos_res.spill_events} "
                 f"spilled_bytes={chaos_res.spilled_bytes} "
                 f"identity=ok reclaim=ok"))

    # --- chunked prefill A/B: 12 long sources head-of-line ahead of 4
    # short interactive ones; burst_len small so the admission encode
    # dominates the first-token edge
    longs = make_corpus(12, cfg.vocab, seed=43, max_words=14)
    shorts = make_corpus(4, cfg.vocab, seed=44, max_words=3)
    mix = longs + shorts
    n_mix = len(mix)
    ceng = ServingEngine(engine.model, engine.params, max_len=32,
                         paged=True, page_size=8)
    serve_chunk = lambda chunk: ceng.serve(
        mix, n_slots=16, max_new_tokens=6, burst_len=2,
        prefill_chunk=chunk)
    measure(lambda: serve_chunk(None), warmup=1, passes=0)
    measure(lambda: serve_chunk(7), warmup=1, passes=0)

    def shorts_worst_first_token(res):
        lats = [r.first_token_latency_s for r in res.requests[len(longs):]]
        assert all(v is not None for v in lats)
        return max(lats)

    mono = chunked = None
    mono_p, chunk_p = [], []
    for _ in range(passes):        # paired passes damp shared-machine noise
        mono = serve_chunk(None)
        chunked = serve_chunk(7)
        mono_p.append(shorts_worst_first_token(mono))
        chunk_p.append(shorts_worst_first_token(chunked))
    for i in range(n_mix):
        assert np.array_equal(mono.tokens_for(i), chunked.tokens_for(i)), (
            f"chunked prefill changed request {i}'s tokens")
    assert chunked.chunked_admissions == len(longs), (
        f"expected every long source staged: {chunked.chunked_admissions}"
        f"/{len(longs)}")
    assert chunked.pages_in_use == 0, "chunked serve leaked pages"
    mono_ft, chunk_ft = min(mono_p), min(chunk_p)
    assert chunk_ft < mono_ft, (
        "chunked prefill must lower the short requests' worst first-token "
        f"latency: monolithic={mono_ft:.4f}s chunked={chunk_ft:.4f}s")
    mono_p50 = float(np.percentile(
        [r.first_token_latency_s for r in mono.requests[len(longs):]], 50))
    chunk_p50 = float(np.percentile(
        [r.first_token_latency_s for r in chunked.requests[len(longs):]], 50))
    rows.append(("preempt_chunked_prefill", 0.0,
                 f"short_first_tok_p50_s={mono_p50:.4f}->{chunk_p50:.4f} "
                 f"worst_s={mono_ft:.4f}->{chunk_ft:.4f} "
                 f"cut={mono_ft / max(chunk_ft, 1e-9):.2f}x "
                 f"chunked_admissions={chunked.chunked_admissions} "
                 f"chunk_rounds={chunked.chunk_rounds} "
                 f"encoder_tokens={mono.encoder_tokens}->"
                 f"{chunked.encoder_tokens}"))
    return rows


def _weightbits_rows(smoke: bool, passes: int) -> list:
    """INT8 vs block-wise INT4 weights through ``serve`` (ISSUE 10).

    Same continuous-batching workload on the same trained-shape model with
    per-channel INT8 weights vs the INT4 layout (decoder FFN + o_proj at
    G=128, f16 scale/min).  Hard invariants for the CI smoke step:

    * ≥1.9× fewer weight bytes on the INT4-eligible sites, and
    * **unchanged** ``host_syncs`` — the byte cut must ride the existing
      fused decode bursts, not buy throughput by changing dispatch shape.
    """
    from repro.core import (QuantPolicy, count_quantized, int4_eligible_site,
                            quantize_model, weight_bytes_by_site)

    # the INT4 layout needs K ≥ group_size on the eligible GEMMs to clear
    # the byte-cut bar (G=128 edge-pads smaller layers), so this section
    # sizes its own model instead of reusing the d_model=96 bench engine
    cfg = get_config("transformer-base").reduced(
        vocab=64, d_model=128, n_layers=2, n_enc_layers=2, d_ff=256,
        n_heads=4, n_kv_heads=4, head_dim=32)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    q8, ctx8 = quantize_model(params, {}, QuantPolicy(act_quant="dynamic"))
    q4, ctx4 = quantize_model(params, {}, QuantPolicy(act_quant="dynamic"),
                              weight_bits=4, weight_group_size=128)
    assert count_quantized(q4)["int4_linears"] == 4 * cfg.n_layers

    b8 = weight_bytes_by_site(q8)
    b4 = weight_bytes_by_site(q4)
    elig = [s for s in b8 if int4_eligible_site(s)]
    cut = sum(b8[s] for s in elig) / max(sum(b4[s] for s in elig), 1)
    assert cut >= 1.9, (
        f"INT4 weight-byte cut {cut:.2f}x < 1.9x on the eligible sites")

    n = 12 if smoke else 32
    reqs = make_corpus(n, cfg.vocab, seed=11)
    caps = [8] * n
    rows = []
    results = {}
    for name, pp, qq in [("int8", q8, ctx8), ("int4", q4, ctx4)]:
        eng = ServingEngine(model, pp, quant=qq, max_len=64)
        res, times, warm = measure(
            lambda: eng.serve(reqs, n_slots=4, max_new_tokens=caps,
                              burst_len=8),
            warmup=1, passes=passes)
        results[name] = res
        wb = sum((b4 if name == "int4" else b8)[s] for s in elig)
        rows.append((f"serve_weight_bits_{name}", min(times) * 1e6 / n,
                     f"tok_per_s={res.n_tokens / min(times):.1f} "
                     f"host_syncs={res.host_syncs} "
                     f"eligible_weight_bytes={wb} compile_s={warm:.2f}"))
    assert results["int4"].host_syncs == results["int8"].host_syncs, (
        "INT4 weights changed the dispatch shape: host_syncs "
        f"int4={results['int4'].host_syncs} int8={results['int8'].host_syncs}")
    assert results["int4"].n_tokens > 0
    rows.append(("serve_weight_bits_cut", 0.0,
                 f"eligible_byte_cut={cut:.2f}x host_syncs_unchanged=1"))
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small fast configuration for CI")
    ap.add_argument("--only", default=None, metavar="SUBSTR",
                    help="run only sections whose name contains SUBSTR "
                         "(pack, continuous, fused, bucket, prefix, "
                         "preempt, weightbits)")
    args = ap.parse_args()
    for r in run(smoke=args.smoke, only=args.only):
        print(",".join(str(x) for x in r))
