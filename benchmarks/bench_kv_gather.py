"""Paper §5.3 — quantized GatherNd (beam-search cache reorder).

The paper cut the decoder while-loop's GatherNd copy volume 3.8× and its
runtime 5× by gathering INT8 data.  TPU analogue: the beam reorder
(`kv_cache.gather_beams`) moves the whole KV cache along the batch axis;
with an int8 cache it moves 4× fewer bytes than f32 (2× vs bf16).

The **paged** cache takes the same optimization to its endpoint: the
reorder becomes a (B, maxP) int32 block-table permutation plus one
partial-page copy per row (`kv_cache.gather_beams_paged`) — the payload
slab stops moving entirely, independent of dtype.  The paged rows report
the exact per-step bytes and **assert ≥ 10×** fewer bytes than the slab
gather of the same cache (the CI bench-smoke step runs this file).

Reports, per cache dtype: bytes moved (exact) + measured CPU gather time.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

import time

from benchmarks.common import time_fn
from repro.models import kv_cache as kvc

L, B, S, H, DH = 4, 32, 512, 8, 64
PAGE_SIZE = 16


def _time_donating(fn, cache, idx, warmup: int = 2, iters: int = 10) -> float:
    """Like ``common.time_fn`` but rebinds the donated cache each call
    (a donated buffer may not be passed twice)."""
    for _ in range(warmup):
        cache = fn(cache, idx)
    jax.block_until_ready(cache.k)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        cache = fn(cache, idx)
        jax.block_until_ready(cache.k)
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def _mk_cache(rng, dtype, L=L, B=B, S=S, H=H, dh=DH):
    quantized = dtype == jnp.int8
    cache = kvc.init_cache(L, B, S, H, dh, quantized=quantized,
                           dtype=dtype if not quantized else jnp.bfloat16)
    if quantized:
        cache = kvc.KVCache(
            k=jnp.asarray(rng.integers(-127, 128, cache.k.shape), jnp.int8),
            v=jnp.asarray(rng.integers(-127, 128, cache.v.shape), jnp.int8),
            k_scale=jnp.asarray(rng.uniform(0.001, 0.02,
                                            cache.k_scale.shape), jnp.float32),
            v_scale=jnp.asarray(rng.uniform(0.001, 0.02,
                                            cache.v_scale.shape), jnp.float32),
            lengths=jnp.full((B,), S, jnp.int32))
    else:
        cache = kvc.KVCache(
            k=jnp.asarray(rng.normal(size=cache.k.shape), dtype),
            v=jnp.asarray(rng.normal(size=cache.v.shape), dtype),
            k_scale=None, v_scale=None,
            lengths=jnp.full((B,), S, jnp.int32))
    return cache


def _mk_paged(rng, dtype, L=L, B=B, S=S, H=H, dh=DH, ps=PAGE_SIZE):
    quantized = dtype == jnp.int8
    cache = kvc.init_paged_cache(
        L, B, S, H, dh, page_size=ps, quantized=quantized,
        dtype=dtype if not quantized else jnp.bfloat16)
    maxP = S // ps
    pages = np.arange(B * maxP, dtype=np.int32).reshape(B, maxP)
    cache = kvc.assign_pages(cache, jnp.arange(B), jnp.asarray(pages))
    fill = (lambda shape, q: jnp.asarray(
        rng.integers(-127, 128, shape), jnp.int8) if q
        else jnp.asarray(rng.normal(size=shape), dtype))
    return kvc.PagedKVCache(
        k=fill(cache.k.shape, quantized), v=fill(cache.v.shape, quantized),
        k_scale=(jnp.asarray(rng.uniform(0.001, 0.02, cache.k_scale.shape),
                             jnp.float32) if quantized else None),
        v_scale=(jnp.asarray(rng.uniform(0.001, 0.02, cache.v_scale.shape),
                             jnp.float32) if quantized else None),
        block_tables=cache.block_tables, own_pages=cache.own_pages,
        lengths=jnp.full((B,), S - ps // 2, jnp.int32))   # mid-page cursor


def run() -> list:
    rng = np.random.default_rng(0)
    beam_idx = jnp.asarray(rng.integers(0, B, (B,)), jnp.int32)
    gather = jax.jit(kvc.gather_beams)
    # donate the paged cache: inside the decode burst the reorder updates
    # the pool in place (the while_loop carries one live copy); without
    # donation the standalone op would copy the whole pool functionally
    # and hide exactly the traffic paging removes
    gather_paged = jax.jit(kvc.gather_beams_paged, donate_argnums=(0,))

    rows = []
    baseline_bytes = baseline_t = None
    for name, dtype in [("f32", jnp.float32), ("bf16", jnp.bfloat16),
                        ("int8", jnp.int8)]:
        cache = _mk_cache(rng, dtype)
        t = time_fn(gather, cache, beam_idx)
        nbytes = cache.nbytes()
        if name == "f32":
            baseline_bytes, baseline_t = nbytes, t
        rows.append((f"s5_3_gather_{name}", t * 1e6,
                     f"bytes={nbytes} "
                     f"bytes_ratio_vs_f32={baseline_bytes / nbytes:.2f} "
                     f"time_ratio_vs_f32={baseline_t / t:.2f}"))

        # paged reorder of the same logical cache: table permutation +
        # one partial-page copy per row — the slab stays put
        paged = _mk_paged(rng, dtype)
        tp = _time_donating(gather_paged, paged, beam_idx)
        pbytes = paged.reorder_bytes_per_step()
        ratio = nbytes / pbytes
        assert ratio >= 10.0, (
            f"paged {name} reorder must move ≥10× fewer bytes than the "
            f"slab gather: {nbytes} vs {pbytes} ({ratio:.1f}×)")
        rows.append((f"s5_3_gather_{name}_paged", tp * 1e6,
                     f"bytes={pbytes} bytes_cut_vs_slab={ratio:.1f}x "
                     f"time_ratio_vs_slab={t / tp:.2f} "
                     f"page_size={PAGE_SIZE}"))
    rows.append(("s5_3_paper_reference", 0.0,
                 "paper: 3.8x copy bytes, 5x op time (f32 -> int8); "
                 "paged block tables: payload stops moving entirely"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
