"""Paper §5.3 — quantized GatherNd (beam-search cache reorder).

The paper cut the decoder while-loop's GatherNd copy volume 3.8× and its
runtime 5× by gathering INT8 data.  TPU analogue: the beam reorder
(`kv_cache.gather_beams`) moves the whole KV cache along the batch axis;
with an int8 cache it moves 4× fewer bytes than f32 (2× vs bf16).

Reports, per cache dtype: bytes moved (exact) + measured CPU gather time.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import time_fn
from repro.models import kv_cache as kvc


def _mk_cache(rng, dtype, L=4, B=32, S=512, H=8, dh=64):
    quantized = dtype == jnp.int8
    cache = kvc.init_cache(L, B, S, H, dh, quantized=quantized,
                           dtype=dtype if not quantized else jnp.bfloat16)
    if quantized:
        cache = kvc.KVCache(
            k=jnp.asarray(rng.integers(-127, 128, cache.k.shape), jnp.int8),
            v=jnp.asarray(rng.integers(-127, 128, cache.v.shape), jnp.int8),
            k_scale=jnp.asarray(rng.uniform(0.001, 0.02,
                                            cache.k_scale.shape), jnp.float32),
            v_scale=jnp.asarray(rng.uniform(0.001, 0.02,
                                            cache.v_scale.shape), jnp.float32),
            lengths=jnp.full((B,), S, jnp.int32))
    else:
        cache = kvc.KVCache(
            k=jnp.asarray(rng.normal(size=cache.k.shape), dtype),
            v=jnp.asarray(rng.normal(size=cache.v.shape), dtype),
            k_scale=None, v_scale=None,
            lengths=jnp.full((B,), S, jnp.int32))
    return cache


def run() -> list:
    rng = np.random.default_rng(0)
    B = 32
    beam_idx = jnp.asarray(rng.integers(0, B, (B,)), jnp.int32)
    gather = jax.jit(kvc.gather_beams)

    rows = []
    baseline_bytes = baseline_t = None
    for name, dtype in [("f32", jnp.float32), ("bf16", jnp.bfloat16),
                        ("int8", jnp.int8)]:
        cache = _mk_cache(rng, dtype)
        t = time_fn(gather, cache, beam_idx)
        nbytes = cache.nbytes()
        if name == "f32":
            baseline_bytes, baseline_t = nbytes, t
        rows.append((f"s5_3_gather_{name}", t * 1e6,
                     f"bytes={nbytes} "
                     f"bytes_ratio_vs_f32={baseline_bytes / nbytes:.2f} "
                     f"time_ratio_vs_f32={baseline_t / t:.2f}"))
    rows.append(("s5_3_paper_reference", 0.0,
                 "paper: 3.8x copy bytes, 5x op time (f32 -> int8)"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
