"""Paper Figure 7 — operation-class split, FP32 graph vs INT8 graph.

The paper profiles op-time percentages (MatMul 43% in FP32; quantized
MatMuls shrink, Quantize/Dequantize overhead appears).  We reproduce the
graph-level view: compile the tiny NMT decode step in both precisions and
classify every HLO op into MatMul / Quantize / Dequantize / Gather /
Softmax-Norm / Other, weighting by output bytes (a dtype-aware proxy for
op cost on a bandwidth-bound decode step), plus measured end-to-end times.
"""

from __future__ import annotations

import re
from collections import defaultdict

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import time_fn, trained_tiny_nmt
from repro.core import QuantPolicy, quantize_model
from repro.core.ptq import FP_CONTEXT
from repro.launch.hlo_analysis import shape_bytes

_CLASSES = [
    ("matmul", ("dot(", "dot-general")),
    ("quantize", ("round-nearest", "clamp(")),
    ("convert", ("convert(",)),
    ("gather", ("gather(", "dynamic-slice(", "dynamic-update-slice(",
                "scatter(")),
    ("softmax_norm", ("exponential(", "divide(", "rsqrt(", "reduce(")),
]


def _classify(hlo: str) -> dict:
    buckets = defaultdict(int)
    for line in hlo.splitlines():
        if "=" not in line:
            continue
        rhs = line.split("=", 1)[1]
        b = shape_bytes(rhs.split(" ", 2)[1] if len(rhs.split(" ", 2)) > 1
                        else rhs)
        kind = "other"
        for name, pats in _CLASSES:
            if any(p in rhs for p in pats):
                kind = name
                break
        buckets[kind] += b
    total = max(sum(buckets.values()), 1)
    return {k: v / total for k, v in sorted(buckets.items())}


def _weight_op_class(site: str) -> str:
    """Bucket a linear site into the op classes of the figure."""
    parts = site.split("/")
    if "ffn" in parts:
        return "dec_ffn" if any(p.startswith("dec_blocks") for p in parts) \
            else "enc_ffn"
    if parts[-1].endswith("_proj"):
        return "dec_attn" if any(p.startswith("dec_blocks") for p in parts) \
            else "enc_attn"
    return "other"


def _weight_bytes_rows(params, qp8, qp4) -> list:
    """Per-op-class weight bytes per precision + the INT8→INT4 cut, so the
    INT4 win is attributable.  The decoder FFN must dominate the savings
    (it is 2·d_ff/d_model of each eligible layer's bytes) — asserted."""
    from repro.core import weight_bytes_by_site

    per = {name: weight_bytes_by_site(pp)
           for name, pp in [("fp32", params), ("int8", qp8), ("int4", qp4)]}
    classes = defaultdict(lambda: defaultdict(int))
    for name, sites in per.items():
        for site, b in sites.items():
            classes[_weight_op_class(site)][name] += b

    rows = []
    savings = {}
    for klass in sorted(classes):
        b = classes[klass]
        savings[klass] = b["int8"] - b["int4"]
        rows.append((f"fig7_weight_bytes_{klass}", 0.0,
                     f"fp32={b['fp32']} int8={b['int8']} int4={b['int4']} "
                     f"int4_cut={b['int8'] / max(b['int4'], 1):.2f}x"))
    total_saved = sum(savings.values())
    assert savings["dec_ffn"] == max(savings.values()), (
        "decoder FFN should dominate the INT4 byte cut", savings)
    rows.append(("fig7_weight_bytes_summary", 0.0,
                 f"dec_ffn_share_of_cut={savings['dec_ffn'] / total_saved:.1%} "
                 f"dec_attn_share={savings['dec_attn'] / total_saved:.1%}"))
    return rows


def run() -> list:
    cfg, model, params, corpus, _ = trained_tiny_nmt()
    qp, qctx = quantize_model(params, {}, QuantPolicy(act_quant="dynamic"))
    qp4, qctx4 = quantize_model(params, {}, QuantPolicy(act_quant="dynamic"),
                                weight_bits=4, weight_group_size=128)
    B = 16
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(3, cfg.vocab, (B,)), jnp.int32)

    rows = []
    for name, pp, qq, quantized in [("fp32", params, FP_CONTEXT, False),
                                    ("int8", qp, qctx, True),
                                    ("int4", qp4, qctx4, True)]:
        state = model.init_decode_state(B, 64, quantized=quantized,
                                        enc_len=32)
        fn = jax.jit(lambda p, t, s: model.decode_step(p, t, s, quant=qq))
        lowered = fn.lower(pp, tokens, state)
        compiled = lowered.compile()
        split = _classify(compiled.as_text())
        t = time_fn(fn, pp, tokens, state)
        detail = " ".join(f"{k}={v:.1%}" for k, v in split.items())
        rows.append((f"fig7_decode_{name}", t * 1e6, detail))
    rows.extend(_weight_bytes_rows(params, qp, qp4))
    rows.append(("fig7_paper_reference", 0.0,
                 "paper: FP32 MatMul 43% -> INT8 adds Quantize/Dequantize, "
                 "shrinks MatMul+GatherNd share"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
