"""Multi-chip serving validation: sharded bursts vs the roofline model.

Runs the SAME skewed request mix through the continuous engine unsharded
and tensor-parallel (``model ∈ {2,4,8}`` forced host devices — the
``launch/dryrun.py`` trick), asserting **bit-identical tokens** and
**unchanged host syncs** (GSPMD's all-reduces stay inside the burst's
``while_loop``; a serve round remains one dispatch + one sync).  Each
mesh row reports the measured per-decode-step time next to
``launch/roofline.sharded_decode_cell``'s prediction.

What is *asserted* vs *reported*: host devices share one CPU, so
measured step time does not follow the TPU constants — the bench only
reports that comparison.  The dimension the host backend models
faithfully is the **collective wire bytes**: the compiled SPMD decode
step is parsed with ``hlo_analysis.analyze_collectives`` and the
per-device ring bytes must match the roofline's analytic
``decode_collective_bytes`` within 2× (asserted, per tp > 1).

A final leg routes the mix across 2 single-mesh engine replicas
(``serving/router.py``), asserting token identity and per-replica
``peak_running`` within 1 of an even split.

Run:  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
          PYTHONPATH=src:. python benchmarks/bench_sharded_serve.py --smoke
(the script sets the flag itself when unset)
"""

from __future__ import annotations

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse

import jax
import numpy as np

from benchmarks.common import measure
from repro.configs import get_config
from repro.core.ptq import FP_CONTEXT
from repro.data import make_corpus
from repro.launch.hlo_analysis import analyze_collectives
from repro.launch.mesh import make_host_mesh
from repro.launch.roofline import sharded_decode_cell
from repro.models import build_model
from repro.serving import ReplicaRouter, ServingEngine

N_REQUESTS = 24
N_SLOTS = 8
MAX_LEN = 64
PAGE_SIZE = 8
SHORT_BUDGET, LONG_BUDGET = 4, 32
MEASURE_PASSES = 3
COLLECTIVE_TOL = 2.0      # asserted: |measured/predicted| within this factor


def _setup(n_requests: int):
    cfg = get_config("transformer-base").reduced(
        vocab=64, d_model=128, n_layers=2, n_enc_layers=2, d_ff=256,
        n_heads=4, n_kv_heads=4, head_dim=32)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    requests = make_corpus(n_requests, cfg.vocab, seed=9, max_words=8)
    rng = np.random.default_rng(0)
    budgets = [int(b) for b in np.where(rng.random(n_requests) < 0.75,
                                        SHORT_BUDGET, LONG_BUDGET)]
    return cfg, model, params, requests, budgets


def _tokens(res):
    return [np.asarray(r.tokens, np.int32) for r in res.requests]


def _engine(model, params, mesh=None):
    return ServingEngine(model, params, quant=FP_CONTEXT, max_len=MAX_LEN,
                         burst_len=8, paged=True, page_size=PAGE_SIZE,
                         mesh=mesh)


def _measured_collective_bytes(model, engine, n_slots: int) -> int:
    """Per-device wire bytes of ONE compiled sharded decode step, parsed
    out of its HLO — the measurement the roofline prediction is checked
    against (ring formulas + while-trip multipliers; a single step has
    none, so this is the per-step figure)."""
    state = engine._shard_state(model.init_decode_state(
        n_slots, engine.max_len, quantized=engine.quant.quantize_kv,
        enc_len=16, paged=True, page_size=engine.page_size,
        n_pages=n_slots * engine._max_pages))
    tokens = np.zeros((n_slots,), np.int32)
    step = jax.jit(lambda p, t, s:
                   model.decode_step(p, t, s, quant=engine.quant))
    txt = step.lower(engine.params, tokens, state).compile().as_text()
    return int(analyze_collectives(txt)["total_bytes"])


def run(smoke: bool) -> None:
    n_requests = 12 if smoke else N_REQUESTS
    tps = (2, 4) if smoke else (2, 4, 8)
    cfg, model, params, requests, budgets = _setup(n_requests)
    n_dev = len(jax.devices())
    print(f"devices: {n_dev}  requests: {n_requests}  "
          f"slots: {N_SLOTS}  model: {cfg.name} (reduced)")

    base = _engine(model, params)
    serve0 = lambda: base.serve(requests, n_slots=N_SLOTS,
                                max_new_tokens=budgets)
    ref, times0, warm0 = measure(serve0, warmup=1, passes=MEASURE_PASSES)
    step0 = min(times0) / max(ref.decode_steps, 1)
    print(f"\n| mesh | step time s | roofline bound s | dominant | "
          f"coll bytes meas | coll bytes pred | identical |")
    print("|---|---|---|---|---|---|---|")
    print(f"| 1 (unsharded) | {step0:.3e} | — | — | 0 | 0 | ref |")

    for tp in tps:
        if tp > n_dev:
            print(f"| {tp} | skipped: only {n_dev} devices |")
            continue
        mesh = make_host_mesh(data=1, model=tp)
        eng = _engine(model, params, mesh=mesh)
        serve = lambda: eng.serve(requests, n_slots=N_SLOTS,
                                  max_new_tokens=budgets)
        res, times, _ = measure(serve, warmup=1, passes=MEASURE_PASSES)

        same = all(np.array_equal(a, b)
                   for a, b in zip(_tokens(ref), _tokens(res)))
        assert same, f"tp={tp}: sharded serve tokens diverged"
        assert res.host_syncs == ref.host_syncs, \
            f"tp={tp}: host syncs {res.host_syncs} != {ref.host_syncs}"

        cell = sharded_decode_cell(cfg, rows=N_SLOTS, tp=tp,
                                   quantized=False)
        meas_coll = _measured_collective_bytes(model, eng, N_SLOTS)
        pred_coll = res.collective_bytes_per_step
        step_s = min(times) / max(res.decode_steps, 1)
        print(f"| {tp} | {step_s:.3e} | {cell['step_time_bound_s']:.3e} "
              f"| {cell['dominant'].split('_')[0]} | {meas_coll} "
              f"| {pred_coll} | {same} |")
        # the host backend compiles real ring collectives — their wire
        # bytes are the dimension the roofline models faithfully
        assert pred_coll > 0, f"tp={tp}: no predicted collective bytes"
        assert meas_coll > 0, f"tp={tp}: compiled step has no collectives"
        ratio = meas_coll / pred_coll
        assert 1 / COLLECTIVE_TOL <= ratio <= COLLECTIVE_TOL, \
            (f"tp={tp}: measured collective bytes {meas_coll} vs predicted "
             f"{pred_coll} (ratio {ratio:.2f}) outside {COLLECTIVE_TOL}x")

    # ------------------------------------------------ data-parallel router
    replicas = 2
    router = ReplicaRouter([_engine(model, params)
                            for _ in range(replicas)])
    rres = router.serve(requests, n_slots=N_SLOTS, max_new_tokens=budgets)
    same = all(np.array_equal(ref.tokens_for(r.req_id),
                              rres.tokens_for(r.req_id))
               for r in rres.requests)
    assert same, "router: tokens diverged from single-engine serve"
    even = n_requests / replicas
    peaks = rres.peak_running_per_replica
    # every replica ran its whole share concurrently (slots >= share), so
    # peak_running == share size: within 1 of an even split
    assert all(abs(p - even) <= 1 for p in peaks), \
        f"router balance: peak_running {peaks} vs even split {even}"
    print(f"\nrouter x{replicas}: peak_running {peaks} (even split {even}), "
          f"tokens/s {rres.tokens_per_s:.1f}, identical: {same}")
    print("\nall sharded-serve assertions passed")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    run(args.smoke)


if __name__ == "__main__":
    main()
