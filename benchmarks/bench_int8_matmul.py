"""Paper Figure 3 — INT8 vs FP32 GEMM across the Transformer's shapes.

The paper measured MKL INT8/VNNI vs FP32/AVX512 (3.7× peak; 2.4× on the
model's shapes).  Here we report, per matmul shape from the Transformer
workload:

* measured CPU wall-time ratio of the XLA int8 path vs f32 (honest, this
  container's hardware — XLA CPU int8 GEMMs are not VNNI-tuned, so this is
  a correctness-cost datapoint, not the TPU story), and
* the derived TPU v5e ratio from hardware constants (394 INT8 TOPS vs
  197 bf16 TFLOPs vs 98.5 f32 TFLOPs → 2× / 4× at compute-bound shapes,
  bandwidth-bound shapes gain from 4× smaller operands).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import time_fn
from repro.core.qtensor import QTensor
from repro.kernels import ops

# (M, K, N) — decoder-step and prefill GEMMs of the paper's transformer-base
SHAPES = [
    (64, 512, 512),        # attention projection, batch 64 decode
    (64, 512, 2048),       # FFN in
    (64, 2048, 512),       # FFN out
    (1024, 512, 512),      # prefill projections
    (1024, 512, 2048),
    (4096, 512, 512),
    (4096, 2048, 512),
]

V5E_INT8_OPS = 394e12
V5E_BF16_FLOPS = 197e12
V5E_F32_FLOPS = 98.5e12
V5E_HBM = 819e9


def derived_tpu_ratio(M, K, N, from_dtype_bytes=4):
    """Roofline-derived INT8/FP32 time ratio on v5e for one GEMM."""
    flops = 2 * M * K * N
    t_f32 = max(flops / V5E_F32_FLOPS,
                (M * K + K * N + M * N) * from_dtype_bytes / V5E_HBM)
    t_s8 = max(flops / V5E_INT8_OPS,
               (M * K + K * N) * 1 / V5E_HBM + M * N * 4 / V5E_HBM)
    return t_f32 / t_s8


def run() -> list:
    rng = np.random.default_rng(0)
    rows = []
    ratios_cpu, ratios_tpu = [], []
    for (M, K, N) in SHAPES:
        a_f = jnp.asarray(rng.normal(size=(M, K)), jnp.float32)
        b_f = jnp.asarray(rng.normal(size=(K, N)), jnp.float32)
        f32_mm = jax.jit(lambda a, b: a @ b)
        t_f32 = time_fn(f32_mm, a_f, b_f)

        a_q = QTensor(jnp.asarray(rng.integers(-127, 128, (M, K)), jnp.int8),
                      jnp.float32(0.01), jnp.zeros(()), None)
        b_q = QTensor(jnp.asarray(rng.integers(-127, 128, (K, N)), jnp.int8),
                      jnp.asarray(rng.uniform(0.001, 0.02, (1, N)),
                                  jnp.float32), jnp.zeros(()), None)
        s8_mm = jax.jit(lambda a, b: ops.int8_matmul(a, b, impl="xla"))
        t_s8 = time_fn(s8_mm, a_q, b_q)

        cpu_ratio = t_f32 / t_s8
        tpu_ratio = derived_tpu_ratio(M, K, N)
        ratios_cpu.append(cpu_ratio)
        ratios_tpu.append(tpu_ratio)
        rows.append((f"fig3_gemm_{M}x{K}x{N}", t_s8 * 1e6,
                     f"cpu_speedup={cpu_ratio:.2f} "
                     f"tpu_derived_speedup={tpu_ratio:.2f}"))
    rows.append(("fig3_geomean", 0.0,
                 f"cpu={np.exp(np.mean(np.log(ratios_cpu))):.2f} "
                 f"tpu_derived={np.exp(np.mean(np.log(ratios_tpu))):.2f} "
                 f"(paper: 2.4x avg / 3.7x peak)"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
