"""Paper Figure 3 — INT8 vs FP32 GEMM across the Transformer's shapes,
plus the ISSUE-10 weight-bits trajectory (INT8 vs block-wise INT4).

The paper measured MKL INT8/VNNI vs FP32/AVX512 (3.7× peak; 2.4× on the
model's shapes).  Here we report, per matmul shape from the Transformer
workload:

* measured CPU wall-time ratio of the XLA int8 path vs f32 (honest, this
  container's hardware — XLA CPU int8 GEMMs are not VNNI-tuned, so this is
  a correctness-cost datapoint, not the TPU story), and
* the derived TPU v5e ratio from hardware constants (394 INT8 TOPS vs
  197 bf16 TFLOPs vs 98.5 f32 TFLOPs → 2× / 4× at compute-bound shapes,
  bandwidth-bound shapes gain from 4× smaller operands).

The ``weight_bits`` section A/Bs per-channel INT8 weights against the
block-wise INT4 layout (G=128, f16 scale/min pairs) on the same GEMM
shapes *and* end-to-end on the tiny trained NMT model: per-config weight
bytes, tokens/s and BLEU go into ``BENCH_weight_bits.json`` (via
``--json``), and the ≥1.9× weight-byte cut + BLEU parity are **asserted**
so the CI smoke step fails on a layout or accuracy regression.
"""

from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import time_fn
from repro.core.qtensor import QTensor, quantize_block
from repro.kernels import ops

# (M, K, N) — decoder-step and prefill GEMMs of the paper's transformer-base
SHAPES = [
    (64, 512, 512),        # attention projection, batch 64 decode
    (64, 512, 2048),       # FFN in
    (64, 2048, 512),       # FFN out
    (1024, 512, 512),      # prefill projections
    (1024, 512, 2048),
    (4096, 512, 512),
    (4096, 2048, 512),
]

V5E_INT8_OPS = 394e12
V5E_BF16_FLOPS = 197e12
V5E_F32_FLOPS = 98.5e12
V5E_HBM = 819e9


def derived_tpu_ratio(M, K, N, from_dtype_bytes=4):
    """Roofline-derived INT8/FP32 time ratio on v5e for one GEMM."""
    flops = 2 * M * K * N
    t_f32 = max(flops / V5E_F32_FLOPS,
                (M * K + K * N + M * N) * from_dtype_bytes / V5E_HBM)
    t_s8 = max(flops / V5E_INT8_OPS,
               (M * K + K * N) * 1 / V5E_HBM + M * N * 4 / V5E_HBM)
    return t_f32 / t_s8


def run() -> list:
    rng = np.random.default_rng(0)
    rows = []
    ratios_cpu, ratios_tpu = [], []
    for (M, K, N) in SHAPES:
        a_f = jnp.asarray(rng.normal(size=(M, K)), jnp.float32)
        b_f = jnp.asarray(rng.normal(size=(K, N)), jnp.float32)
        f32_mm = jax.jit(lambda a, b: a @ b)
        t_f32 = time_fn(f32_mm, a_f, b_f)

        a_q = QTensor(jnp.asarray(rng.integers(-127, 128, (M, K)), jnp.int8),
                      jnp.float32(0.01), jnp.zeros(()), None)
        b_q = QTensor(jnp.asarray(rng.integers(-127, 128, (K, N)), jnp.int8),
                      jnp.asarray(rng.uniform(0.001, 0.02, (1, N)),
                                  jnp.float32), jnp.zeros(()), None)
        s8_mm = jax.jit(lambda a, b: ops.int8_matmul(a, b, impl="xla"))
        t_s8 = time_fn(s8_mm, a_q, b_q)

        cpu_ratio = t_f32 / t_s8
        tpu_ratio = derived_tpu_ratio(M, K, N)
        ratios_cpu.append(cpu_ratio)
        ratios_tpu.append(tpu_ratio)
        rows.append((f"fig3_gemm_{M}x{K}x{N}", t_s8 * 1e6,
                     f"cpu_speedup={cpu_ratio:.2f} "
                     f"tpu_derived_speedup={tpu_ratio:.2f}"))
    rows.append(("fig3_geomean", 0.0,
                 f"cpu={np.exp(np.mean(np.log(ratios_cpu))):.2f} "
                 f"tpu_derived={np.exp(np.mean(np.log(ratios_tpu))):.2f} "
                 f"(paper: 2.4x avg / 3.7x peak)"))
    return rows


# ---------------------------------------------------------------------------
# weight-bits trajectory: per-channel INT8 vs block-wise INT4 (ISSUE 10)
# ---------------------------------------------------------------------------

BYTE_CUT_FLOOR = 1.9       # CI-asserted weight-byte cut on the decoder GEMMs
REL_BLEU_DROP = 0.005      # the paper's <0.5% relative bar, reused for INT4
INT4_GROUP = 128


def derived_tpu_ratio_int4(M, K, N, group_size=INT4_GROUP, scale_bytes=2):
    """Roofline-derived INT4/INT8 time ratio on v5e for one weight-streaming
    GEMM (nibbles feed the same s8×s8 MXU path, so only the weight-byte
    term moves)."""
    flops = 2 * M * K * N
    per_w = 0.5 + 2.0 * scale_bytes / group_size
    t_s8 = max(flops / V5E_INT8_OPS,
               (M * K + K * N) / V5E_HBM + M * N * 4 / V5E_HBM)
    t_s4 = max(flops / V5E_INT8_OPS,
               (M * K + K * N * per_w) / V5E_HBM + M * N * 4 / V5E_HBM)
    return t_s8 / t_s4


def _trained_for_bleu():
    """Train the parity-test model: short sentences the tiny transformer can
    actually learn (corpus BLEU ~70), so the INT4-vs-FP gate is meaningful."""
    from repro.configs import get_config
    from repro.data import TranslationBatches, make_corpus
    from repro.models import build_model
    from repro.optim import AdamW
    from repro.optim.schedule import inverse_sqrt
    from repro.train import make_train_step

    cfg = get_config("transformer-base").reduced(
        vocab=64, d_model=128, n_layers=2, n_enc_layers=2, d_ff=256,
        n_heads=4, n_kv_heads=4, head_dim=32)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = AdamW(lr=inverse_sqrt(cfg.d_model, warmup=200), b2=0.98)
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(model, opt))
    corpus = make_corpus(400, cfg.vocab, max_words=5, seed=0)
    data = TranslationBatches(corpus, 32, sort_mode="tokens", seed=0)
    for _ in range(500):
        batch = jax.tree_util.tree_map(jnp.asarray, data.next_batch())
        (params, opt_state), _ = step(params, opt_state, batch)
    return cfg, model, params, corpus


def run_weight_bits(smoke: bool = False) -> tuple:
    """Per-GEMM and end-to-end INT8 vs INT4 rows + machine-readable record.

    Returns ``(rows, record)``; asserts the exact INT4 byte layout on every
    benched GEMM, the ≥1.9× weight-byte cut on the eligible model sites,
    and BLEU parity (<0.5% relative vs FP) through the serving engine on a
    tiny trained model.
    """
    from benchmarks.common import translate_all
    from repro.core import (QuantPolicy, count_quantized, int4_eligible_site,
                            quantize_model, weight_bytes_by_site)
    from repro.data import corpus_bleu

    rng = np.random.default_rng(0)
    rows, configs = [], []
    shapes = SHAPES[:3] if smoke else SHAPES
    iters = 3 if smoke else 10
    for (M, K, N) in shapes:
        w = jnp.asarray(rng.normal(size=(K, N)), jnp.float32)
        b8 = QTensor(jnp.asarray(rng.integers(-127, 128, (K, N)), jnp.int8),
                     jnp.asarray(rng.uniform(0.001, 0.02, (1, N)),
                                 jnp.float32), jnp.zeros(()), None)
        b4 = quantize_block(w, group_size=INT4_GROUP,
                            scale_dtype=jnp.float16)
        a_q = QTensor(jnp.asarray(rng.integers(-127, 128, (M, K)), jnp.int8),
                      jnp.float32(0.01), jnp.zeros(()), None)
        t8 = time_fn(jax.jit(lambda a, b: ops.int8_matmul(a, b, impl="xla")),
                     a_q, b8, iters=iters)
        t4 = time_fn(jax.jit(lambda a, b: ops.int4_matmul(a, b, impl="xla")),
                     a_q, b4, iters=iters)
        byte_cut = b8.nbytes() / b4.nbytes()
        tpu_ratio = derived_tpu_ratio_int4(M, K, N)
        # Exact layout guard: 0.5 B/weight payload + f16 (scale, min) per
        # group.  The flat >=1.9x gate lives on the eligible *model* sites
        # below (small-K layers); at large K the per-GEMM cut asymptotes to
        # 8/4.25 = 1.88x because the int8 per-channel scale amortizes away.
        n_g = -(-K // INT4_GROUP)
        expect_b4 = K * N // 2 + 2 * n_g * N * 2
        assert b4.nbytes() == expect_b4, (
            f"INT4 layout regression on {M}x{K}x{N}: {b4.nbytes()} B "
            f"!= expected {expect_b4} B")
        assert byte_cut >= 1.85, (
            f"INT4 weight-byte cut {byte_cut:.2f}x < 1.85x on {M}x{K}x{N}")
        rows.append((f"weight_bits_gemm_{M}x{K}x{N}", t4 * 1e6,
                     f"byte_cut={byte_cut:.2f}x "
                     f"cpu_int4_vs_int8={t8 / t4:.2f} "
                     f"tpu_derived_int4_vs_int8={tpu_ratio:.2f}"))
        configs.append({
            "kind": "gemm", "M": M, "K": K, "N": N,
            "weight_bytes_int8": int(b8.nbytes()),
            "weight_bytes_int4": int(b4.nbytes()),
            "byte_cut": round(byte_cut, 4),
            "tpu_derived_speedup": round(tpu_ratio, 4),
            "cpu_int4_us": round(t4 * 1e6, 2),
            "cpu_int8_us": round(t8 * 1e6, 2),
        })

    # end-to-end: tokens/s + BLEU through the serving engine, FP vs INT8
    # vs INT4 on the same trained params.  Uses the parity-test training
    # recipe (400 sentences, max_words=5, 500 steps) rather than
    # ``trained_tiny_nmt`` — the latter's longer corpus leaves the tiny
    # model near-uniform (BLEU-4 = 0), which would make the parity gate
    # below vacuous.
    cfg, model, params, corpus = _trained_for_bleu()
    test_set = corpus[:24 if smoke else 64]
    refs = [list(s.tgt) for s in test_set]
    q8, ctx8 = quantize_model(params, {}, QuantPolicy(act_quant="dynamic"))
    q4, ctx4 = quantize_model(params, {}, QuantPolicy(act_quant="dynamic"),
                              weight_bits=4, weight_group_size=INT4_GROUP)
    assert count_quantized(q4)["int4_linears"] == 4 * cfg.n_layers

    b8_site = weight_bytes_by_site(q8)
    b4_site = weight_bytes_by_site(q4)
    elig = [s for s in b8_site if int4_eligible_site(s)]
    cut = (sum(b8_site[s] for s in elig)
           / max(sum(b4_site[s] for s in elig), 1))
    assert cut >= BYTE_CUT_FLOOR, (
        f"eligible-site weight-byte cut {cut:.2f}x < {BYTE_CUT_FLOOR}x")

    bleu = {}
    for name, pp, qq in [("fp", params, None), ("int8", q8, ctx8),
                         ("int4", q4, ctx4)]:
        hyps, dt = translate_all(model, pp, qq, test_set, max_new=16)
        n_tok = sum(len(h) for h in hyps)
        bleu[name] = corpus_bleu(hyps, refs)
        stats = count_quantized(pp) if qq else {"int4_bytes": 0}
        rows.append((f"weight_bits_serve_{name}", dt * 1e6 / len(test_set),
                     f"tok_per_s={n_tok / dt:.1f} bleu={bleu[name]:.2f}"))
        configs.append({
            "kind": "serve", "weights": name,
            "tokens_per_s": round(n_tok / dt, 2),
            "bleu": round(float(bleu[name]), 4),
            "weight_bytes_eligible": int(sum(
                (b4_site if name == "int4" else b8_site).get(s, 0)
                for s in elig)),
            "int4_bytes": int(stats.get("int4_bytes", 0)),
        })
    assert bleu["fp"] > 10.0, (
        f"FP baseline BLEU {bleu['fp']:.2f} too low — the parity gate "
        "below would be vacuous")
    assert bleu["int4"] >= bleu["fp"] * (1.0 - REL_BLEU_DROP), (
        f"INT4 BLEU {bleu['int4']:.2f} fell below the "
        f"{REL_BLEU_DROP:.1%} relative bar vs FP {bleu['fp']:.2f}")
    rows.append(("weight_bits_summary", 0.0,
                 f"eligible_byte_cut={cut:.2f}x "
                 f"bleu_fp={bleu['fp']:.2f} bleu_int4={bleu['int4']:.2f}"))
    record = {
        "bench": "weight_bits",
        "group_size": INT4_GROUP,
        "scale_dtype": "float16",
        "eligible_byte_cut": round(cut, 4),
        "byte_cut_floor": BYTE_CUT_FLOOR,
        "configs": configs,
    }
    return rows, record


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fewer shapes/requests + fewer timing iters (CI)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the weight-bits record (BENCH_weight_bits"
                         ".json) to PATH")
    args = ap.parse_args()
    rows = [] if args.smoke else run()   # smoke: weight-bits section only
    wb_rows, record = run_weight_bits(smoke=args.smoke)
    for r in rows + wb_rows:
        print(",".join(str(x) for x in r))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(record, f, indent=2, sort_keys=True)
        print(f"wrote {args.json}")
