"""Shared benchmark utilities: timing + the tiny trained NMT model every
accuracy benchmark reuses (trained once, cached in-process)."""

from __future__ import annotations

import functools
import time
from typing import Callable, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def time_fn(fn: Callable, *args, warmup: int = 2, iters: int = 10) -> float:
    """Median wall-clock seconds per call (after jit warmup)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def measure(fn: Callable, *, warmup: int = 1, passes: int = 3):
    """Time a serving-level callable, keeping compile out of the measurement.

    The first ``warmup`` calls absorb jit compilation (and are timed so the
    caller can *report* compile cost separately instead of folding it into
    throughput); the next ``passes`` calls are measured.  Returns
    ``(last_result, measured_times_list, warmup_s)`` — callers typically
    take ``min`` or ``median`` of the times.  ``fn`` must return host-side
    results (e.g. ``ServeResult``/``GenerationResult``), so each call is
    already synchronized.

    ``warmup`` must be ≥ 1 whenever anything is measured: with no warmup
    call, jit compilation lands in the first measured pass and silently
    skews every downstream number.  (``passes=0`` with ``warmup≥1`` is the
    sanctioned compile-only / correctness-only use.)
    """
    if warmup < 1 and passes > 0:
        raise ValueError(
            f"measure(warmup={warmup}) would fold jit compile into the "
            "first measured pass; use warmup >= 1 (or passes=0 for an "
            "unmeasured call)")
    t0 = time.perf_counter()
    for _ in range(warmup):
        fn()
    warmup_s = time.perf_counter() - t0
    times, out = [], None
    for _ in range(passes):
        t0 = time.perf_counter()
        out = fn()
        times.append(time.perf_counter() - t0)
    return out, times, warmup_s


@functools.lru_cache(maxsize=1)
def trained_tiny_nmt(steps: int = 900):
    """Train the paper's model (reduced) on the synthetic corpus once."""
    from repro.configs import get_config
    from repro.data import TranslationBatches, make_corpus
    from repro.models import build_model
    from repro.optim import AdamW
    from repro.optim.schedule import inverse_sqrt
    from repro.train import make_train_step

    cfg = get_config("transformer-base").reduced(
        vocab=64, d_model=128, n_layers=2, n_enc_layers=2, d_ff=256,
        n_heads=4, n_kv_heads=4, head_dim=32)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    # the paper's model's own recipe (inverse-sqrt warmup, Adam b2=0.98)
    opt = AdamW(lr=inverse_sqrt(cfg.d_model, warmup=200), b2=0.98)
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(model, opt))
    corpus = make_corpus(600, cfg.vocab, max_words=6, seed=0)
    data = TranslationBatches(corpus, 32, sort_mode="tokens", seed=0)
    loss = None
    for _ in range(steps):
        batch = jax.tree_util.tree_map(jnp.asarray, data.next_batch())
        (params, opt_state), m = step(params, opt_state, batch)
        loss = float(m["loss"])
    return cfg, model, params, corpus, loss


def translate_all(model, params, qctx, requests, *, batch_size=16,
                  max_new=24, warmup: bool = True
                  ) -> Tuple[List[list], float]:
    """Translate requests with the serving engine; returns (hyps, seconds).

    ``warmup`` runs one short generate per distinct batch shape first, so
    jit compilation is excluded from the reported seconds (each engine has
    its own jit cache — without this, the first call per shape folds
    compile into the throughput numbers).
    """
    from repro.core.ptq import FP_CONTEXT
    from repro.serving import ServingEngine, TokenSortedScheduler
    engine = ServingEngine(model, params, quant=qctx or FP_CONTEXT,
                           max_len=96)
    sched = TokenSortedScheduler(batch_size=batch_size)
    items = sched.plan(requests)
    if warmup:
        seen = set()
        for item in items:
            shape = item.batch["src_tokens"].shape
            if shape not in seen:
                seen.add(shape)
                engine.generate(item.batch, max_new_tokens=2)
    hyps = {}
    t0 = time.perf_counter()
    for item in items:
        res = engine.generate(item.batch, max_new_tokens=max_new)
        for local, gi in enumerate(item.indices):
            hyps[gi] = list(res.tokens[local])
    dt = time.perf_counter() - t0
    return [hyps[i] for i in range(len(requests))], dt
