"""Shared benchmark utilities: timing + the tiny trained NMT model every
accuracy benchmark reuses (trained once, cached in-process)."""

from __future__ import annotations

import functools
import time
from typing import Callable, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def time_fn(fn: Callable, *args, warmup: int = 2, iters: int = 10) -> float:
    """Median wall-clock seconds per call (after jit warmup)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


@functools.lru_cache(maxsize=1)
def trained_tiny_nmt(steps: int = 900):
    """Train the paper's model (reduced) on the synthetic corpus once."""
    from repro.configs import get_config
    from repro.data import TranslationBatches, make_corpus
    from repro.models import build_model
    from repro.optim import AdamW
    from repro.optim.schedule import inverse_sqrt
    from repro.train import make_train_step

    cfg = get_config("transformer-base").reduced(
        vocab=64, d_model=128, n_layers=2, n_enc_layers=2, d_ff=256,
        n_heads=4, n_kv_heads=4, head_dim=32)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    # the paper's model's own recipe (inverse-sqrt warmup, Adam b2=0.98)
    opt = AdamW(lr=inverse_sqrt(cfg.d_model, warmup=200), b2=0.98)
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(model, opt))
    corpus = make_corpus(600, cfg.vocab, max_words=6, seed=0)
    data = TranslationBatches(corpus, 32, sort_mode="tokens", seed=0)
    loss = None
    for _ in range(steps):
        batch = jax.tree_util.tree_map(jnp.asarray, data.next_batch())
        (params, opt_state), m = step(params, opt_state, batch)
        loss = float(m["loss"])
    return cfg, model, params, corpus, loss


def translate_all(model, params, qctx, requests, *, batch_size=16,
                  max_new=24) -> Tuple[List[list], float]:
    """Translate requests with the serving engine; returns (hyps, seconds)."""
    from repro.core.ptq import FP_CONTEXT
    from repro.serving import ServingEngine, TokenSortedScheduler
    engine = ServingEngine(model, params, quant=qctx or FP_CONTEXT,
                           max_len=96)
    sched = TokenSortedScheduler(batch_size=batch_size)
    items = sched.plan(requests)
    hyps = {}
    t0 = time.perf_counter()
    for item in items:
        res = engine.generate(item.batch, max_new_tokens=max_new)
        for local, gi in enumerate(item.indices):
            hyps[gi] = list(res.tokens[local])
    dt = time.perf_counter() - t0
    return [hyps[i] for i in range(len(requests))], dt
