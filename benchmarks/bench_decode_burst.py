"""Decode-burst sweep: host-sync overhead vs slot-refill latency.

The per-token serving loop pays one jitted dispatch plus one device→host
synchronization per generated token; the decode-burst engine fuses up to
``K`` steps into one on-device ``lax.while_loop`` and returns to the host
only at burst boundaries.  This benchmark sweeps ``K ∈ {1,2,4,8,16,32}``
over ``ServingEngine.serve`` (continuous batching, skewed generation
lengths) and ``generate`` (one static batch) on a deliberately small
**CPU test config**, where per-step device compute is tiny and framework
dispatch dominates — the regime the paper's §5.5 and Quinn & Ballesteros
(arXiv:1804.05038) identify for small per-step work.

The tradeoff the sweep exposes: larger bursts cut ``host_syncs`` linearly
but delay slot refill to burst edges, so rows that finish mid-burst idle
(masked to EOS) and ``decode_steps``/utilization degrade.  Throughput
peaks at a middle ``K``; ``K=1`` reproduces the pre-burst per-step path.

Rows (per K): measured serve tokens/s, speedup vs ``K=1``, host syncs,
decode steps, grid utilization — plus greedy **token identity** vs the
``K=1`` output for every swept K, a ``serve_burst_auto`` row where the
``AdaptiveBurst`` controller picks K between bursts (identity asserted),
a ``generate`` sweep, and a best-K summary.  Compile/warmup is timed separately (``compile_warmup`` row) and
excluded from every measured number.  ``--smoke`` shrinks the sweep for CI.
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from benchmarks.common import measure
from repro.configs import get_config
from repro.data import make_corpus
from repro.data.synthetic import pad_batch
from repro.models import build_model
from repro.serving import ServingEngine

KS = (1, 2, 4, 8, 16, 32)
N_REQUESTS = 48
N_SLOTS = 8
SHORT_BUDGET, LONG_BUDGET = 4, 48
P_SHORT = 0.75
MEASURE_PASSES = 3


def _setup(n_requests: int):
    # test-scale model: per-step compute is small, so the per-token
    # dispatch+sync tax is visible (the regime bursts are built for)
    cfg = get_config("transformer-base").reduced(
        vocab=32, d_model=48, n_layers=1, n_enc_layers=1, d_ff=96,
        n_heads=2, n_kv_heads=2, head_dim=24)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServingEngine(model, params, max_len=64)
    requests = make_corpus(n_requests, cfg.vocab, seed=9, max_words=8)
    rng = np.random.default_rng(0)
    budgets = [int(b) for b in np.where(rng.random(n_requests) < P_SHORT,
                                        SHORT_BUDGET, LONG_BUDGET)]
    return engine, requests, budgets


def run(smoke: bool = False) -> list:
    rows = []
    ks = (1, 8) if smoke else KS
    n_requests = 16 if smoke else N_REQUESTS
    passes = 1 if smoke else MEASURE_PASSES
    engine, requests, budgets = _setup(n_requests)

    # ---- serve sweep -----------------------------------------------------
    warm_total = 0.0
    results = {}
    reference = None            # K=1 token streams (pre-burst per-step path)
    for k in ks:
        serve = lambda: engine.serve(requests, n_slots=N_SLOTS,
                                     max_new_tokens=budgets, burst_len=k)
        res, times, warm_s = measure(serve, warmup=1, passes=passes)
        warm_total += warm_s
        wall = min(times)
        tps = res.n_tokens / wall
        results[k] = (res, tps)
        if reference is None:
            reference = [res.tokens_for(i) for i in range(n_requests)]
        mismatches = sum(
            not np.array_equal(res.tokens_for(i), reference[i])
            for i in range(n_requests))
        base_tps = results[ks[0]][1]
        rows.append((f"serve_burst_k{k}", wall * 1e6 / n_requests,
                     f"tok_per_s={tps:.1f} speedup={tps / base_tps:.2f}x "
                     f"host_syncs={res.host_syncs} "
                     f"decode_steps={res.decode_steps} "
                     f"grid_util={res.utilization:.3f} "
                     f"identical_to_k1={mismatches == 0}"))

    best_k = max(results, key=lambda k: results[k][1])
    base_tps = results[ks[0]][1]
    rows.append(("serve_burst_best", 0.0,
                 f"best_k={best_k} "
                 f"speedup={results[best_k][1] / base_tps:.2f}x "
                 f"(tok_per_s {base_tps:.1f} -> {results[best_k][1]:.1f})"))

    # ---- adaptive burst (burst_len="auto"): the AdaptiveBurst controller
    # moves the step cap between bursts under ONE compiled ring bucket;
    # output must stay identical to every fixed K (asserted) ------------
    serve_auto = lambda: engine.serve(requests, n_slots=N_SLOTS,
                                      max_new_tokens=budgets,
                                      burst_len="auto")
    res, times, warm_s = measure(serve_auto, warmup=1, passes=passes)
    warm_total += warm_s
    mismatches = sum(not np.array_equal(res.tokens_for(i), reference[i])
                     for i in range(n_requests))
    assert mismatches == 0, (
        f"burst_len='auto' diverged on {mismatches}/{n_requests} requests")
    rows.append(("serve_burst_auto", min(times) * 1e6 / n_requests,
                 f"tok_per_s={res.n_tokens / min(times):.1f} "
                 f"final_k={res.burst_len} host_syncs={res.host_syncs} "
                 f"speedup={res.n_tokens / min(times) / base_tps:.2f}x "
                 f"identical_to_k1={mismatches == 0}"))

    # ---- self-speculative decoding: INT8-path drafts + one batched
    # verify per macro-step inside the same jitted loop.  CI-asserted:
    # tokens identical to the non-speculative reference, acceptance at
    # least the floor (self-drafting must agree with itself most of the
    # time — a collapse here means the verify path diverged), and host
    # syncs no worse than the plain burst at the same cap (speculation
    # must not add device→host round trips) ----------------------------
    spec_k = 2 if smoke else 4
    base_k = ks[-1]
    serve_spec = lambda: engine.serve(requests, n_slots=N_SLOTS,
                                      max_new_tokens=budgets,
                                      burst_len=base_k,
                                      speculative_k=spec_k)
    res, times, warm_s = measure(serve_spec, warmup=1, passes=passes)
    warm_total += warm_s
    mismatches = sum(not np.array_equal(res.tokens_for(i), reference[i])
                     for i in range(n_requests))
    assert mismatches == 0, (
        f"speculative_k={spec_k} diverged on {mismatches}/{n_requests} "
        "requests — lossless verification broken")
    ACCEPTANCE_FLOOR = 0.5
    assert res.acceptance_rate >= ACCEPTANCE_FLOOR, (
        f"acceptance rate {res.acceptance_rate:.3f} below floor "
        f"{ACCEPTANCE_FLOOR} (draft/verify paths disagree too often)")
    assert res.host_syncs <= results[base_k][0].host_syncs, (
        f"speculation added host syncs: {res.host_syncs} > "
        f"{results[base_k][0].host_syncs}")
    tps = res.n_tokens / min(times)
    rows.append(("serve_speculative", min(times) * 1e6 / n_requests,
                 f"tok_per_s={tps:.1f} spec_k={spec_k} "
                 f"acceptance={res.acceptance_rate:.3f} "
                 f"draft={res.draft_tokens} accepted={res.accepted_tokens} "
                 f"host_syncs={res.host_syncs} "
                 f"speedup={tps / base_tps:.2f}x "
                 f"identical_to_k1={mismatches == 0}"))

    # ---- generate sweep (one static batch, uniform budget) ---------------
    src, lens = pad_batch([s.src for s in requests[:N_SLOTS]])
    batch = {"src_tokens": src, "src_lengths": lens}
    gen_ref = None
    for k in ks:
        gen = lambda: engine.generate(batch, max_new_tokens=LONG_BUDGET,
                                      burst_len=k)
        res, times, warm_s = measure(gen, warmup=1, passes=passes)
        warm_total += warm_s
        tps = res.n_tokens / min(times) if res.n_tokens else 0.0
        if gen_ref is None:
            gen_ref = res.tokens
        mismatches = sum(not np.array_equal(a, b)
                         for a, b in zip(res.tokens, gen_ref))
        rows.append((f"generate_burst_k{k}", min(times) * 1e6,
                     f"tok_per_s={tps:.1f} host_syncs={res.host_syncs} "
                     f"steps_per_s={res.decode_steps_per_s:.0f} "
                     f"identical_to_k1={mismatches == 0}"))

    rows.append(("compile_warmup", 0.0,
                 f"total_s={warm_total:.2f} (excluded from rows above)"))
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small fast configuration for CI")
    args = ap.parse_args()
    for r in run(smoke=args.smoke):
        print(",".join(str(x) for x in r))
