"""Benchmark driver — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Mapping to the paper:

* bench_calibration_modes  → Table 1  (BLEU per quantization mode)
* bench_int8_matmul        → Figure 3 (INT8 vs FP32 GEMM speedups)
* bench_kv_gather          → §5.3     (quantized GatherNd / beam reorder)
* bench_batching           → §5.4 + Figures 6/8 (sorting, parallel streams)
* bench_op_distribution    → Figure 7 (op-class split FP32 vs INT8)
* bench_continuous         → beyond §5.6 (static vs continuous batching)
* bench_decode_burst       → beyond §5.5 (on-device decode bursts vs
                             per-token host dispatch)
* bench_beam_serve         → §5.3 serving-side (continuous beam groups vs
                             per-request beam search, FP and INT8 cache)
"""

import sys
import time
import traceback


def main() -> None:
    from benchmarks import (
        bench_batching,
        bench_beam_serve,
        bench_calibration_modes,
        bench_continuous,
        bench_decode_burst,
        bench_int8_matmul,
        bench_kv_gather,
        bench_op_distribution,
    )
    modules = [
        ("table1", bench_calibration_modes),
        ("fig3", bench_int8_matmul),
        ("s5.3", bench_kv_gather),
        ("fig6/8", bench_batching),
        ("fig7", bench_op_distribution),
        ("continuous", bench_continuous),
        ("burst", bench_decode_burst),
        ("beam", bench_beam_serve),
    ]
    only = sys.argv[1] if len(sys.argv) > 1 else None
    print("name,us_per_call,derived")
    failures = 0
    for tag, mod in modules:
        if only and only not in tag and only not in mod.__name__:
            continue
        t0 = time.time()
        try:
            for row in mod.run():
                print(",".join(str(x) for x in row), flush=True)
        except Exception as e:  # pragma: no cover
            failures += 1
            print(f"{mod.__name__},ERROR,{e!r}", flush=True)
            traceback.print_exc(file=sys.stderr)
        print(f"# {mod.__name__} finished in {time.time() - t0:.1f}s",
              flush=True)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
