"""Paper §5.4 + Figures 6/8 — input ordering and parallel batching.

Three reproductions:

1. **Padding waste** (§5.4): unsorted vs word-sorted vs token-sorted
   batching over the synthetic corpus (the paper reports +28% throughput
   for token over word sorting; padding waste is the hardware-independent
   cause).
2. **Measured throughput** on the tiny trained NMT model: token-sorted vs
   unsorted serving on this CPU.
3. **Serial vs parallel streams** (Fig 6/8): per-batch decode costs are
   measured once, then the stream-queue model reports makespan/utilization
   for 1/2/4/8 streams (a threaded 2-stream run is also measured — on one
   CPU core it shows the *mechanism*, the model shows the scaling).
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import trained_tiny_nmt, translate_all
from repro.data import make_batches, make_corpus, padding_stats
from repro.serving import (
    ParallelStreams,
    ServingEngine,
    TokenSortedScheduler,
    simulate_streams,
)


def run() -> list:
    rows = []
    corpus = make_corpus(1200, vocab=256, seed=7)

    # 1 — padding waste
    stats = {}
    for mode in ("none", "words", "tokens"):
        stats[mode] = padding_stats(corpus, make_batches(corpus, 64, mode))
        rows.append((f"s5_4_padding_{mode}", 0.0,
                     f"pad_waste={stats[mode]['pad_waste']:.4f}"))
    comp_reduction = (stats["none"]["padded_tokens"]
                      / stats["tokens"]["padded_tokens"])
    rows.append(("s5_4_token_vs_none_compute", 0.0,
                 f"padded_token_reduction={comp_reduction:.3f}x "
                 f"(paper: +28% throughput token vs word sorting)"))

    # 2 — measured throughput, sorted vs unsorted (tiny model, this CPU)
    cfg, model, params, train_corpus, _ = trained_tiny_nmt()
    requests = train_corpus[:128]
    hyp_u, t_unsorted = translate_all(model, params, None, requests)
    # token-sorted path is what translate_all uses; compare with shuffled
    # batches of identical content via sort_mode none
    from repro.serving import TokenSortedScheduler
    from repro.core.ptq import FP_CONTEXT
    engine = ServingEngine(model, params, max_len=96)
    for mode in ("none", "tokens"):
        sched = TokenSortedScheduler(batch_size=16, sort_mode=mode)
        items = sched.plan(requests)
        import time
        t0 = time.perf_counter()
        n_tok = 0
        for item in items:
            res = engine.generate(item.batch, max_new_tokens=24)
            n_tok += res.n_tokens
        dt = time.perf_counter() - t0
        rows.append((f"fig8_measured_{mode}_sorted", dt * 1e6 / len(requests),
                     f"sentences_per_s={len(requests) / dt:.2f}"))

    # 3 — serial vs parallel streams (queueing model on measured costs)
    sched = TokenSortedScheduler(batch_size=16)
    items = sched.plan(requests)
    costs = []
    for item in items:
        import time
        t0 = time.perf_counter()
        engine.generate(item.batch, max_new_tokens=24)
        costs.append(time.perf_counter() - t0)
    for n in (1, 2, 4, 8):
        sim = simulate_streams(costs, n)
        rows.append((f"fig6_streams_{n}", sim["makespan_s"] * 1e6,
                     f"speedup={sim['speedup_vs_serial']:.2f} "
                     f"util={sim['utilization']:.2f}"))

    # threaded 2-stream mechanism check (GIL-bound on 1 core: mechanism only)
    ps = ParallelStreams(
        lambda sid, item: engine.generate(item.batch,
                                          max_new_tokens=24).n_tokens,
        n_streams=2)
    out = ps.run(items)
    rows.append(("fig6_threaded_2stream", out["makespan_s"] * 1e6,
                 f"util={out['utilization']:.2f} "
                 f"tok_per_s={out['throughput_tok_s']:.1f}"))
    rows.append(("fig6_paper_reference", 0.0,
                 "paper: +43% throughput from parallel batching; "
                 "best config 1.51x vs best FP32"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
